"""Fused LUT-GEMM serve kernels + roofline block autotuner.

Covers the fused epilogue contract (Y = act(X @ dequant(packed) + bias) +
residual as ONE kernel dispatch): parity vs the unfused oracle across
activations, bias/residual combinations, non-divisible shapes (the M/N
padding path) and pack-block-multiple ``block_k``; the ValueError shape
diagnostics (formerly bare asserts that vanished under ``python -O``); the
roofline autotuner's sweep space, model sanity and cache round-trip; and the
serving integration — fused serve artifacts attached to an LM comp tree and
the engine's ``lut_serve`` mode reproducing fake-quant tokens exactly.
"""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.export import export_layer, serve_dense
from repro.core import qat
from repro.kernels.lut_matmul import autotune as at
from repro.kernels.lut_matmul.lut_matmul import ACTIVATIONS, lut_matmul_pallas
from repro.kernels.lut_matmul.ops import (
    compress_layer_weights,
    lut_matmul,
    lut_matmul_fused,
)
from repro.kernels.lut_matmul.ref import lut_matmul_fused_ref, lut_matmul_ref

VALUES = [-112, -80, -56, -40, -28, -16, -8, 0, 8, 16, 28, 40, 56, 80, 112,
          127]


def _problem(m, k, n, seed=0, pad_k=False):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (k, n)) * 0.05
    packed, cb, scale = compress_layer_weights(w, VALUES, block_k=128,
                                               pad_k=pad_k)
    x = jax.random.normal(jax.random.fold_in(key, 1),
                          (m, 2 * packed.shape[0]))
    bias = jax.random.normal(jax.random.fold_in(key, 2), (n,)) * 0.1
    res = jax.random.normal(jax.random.fold_in(key, 3), (m, n))
    return x, packed, cb, scale, bias, res


def rel_err(got, want):
    return float(jnp.linalg.norm(got - want)
                 / jnp.maximum(jnp.linalg.norm(want), 1e-9))


# ------------------------------------------------------------- fused epilogue


@pytest.mark.parametrize("activation", sorted(ACTIVATIONS))
@pytest.mark.parametrize("with_bias,with_res",
                         [(False, False), (True, False), (True, True)])
def test_fused_kernel_matches_fused_ref(activation, with_bias, with_res):
    x, packed, cb, scale, bias, res = _problem(16, 256, 128)
    kwargs = dict(bias=bias if with_bias else None,
                  residual=res if with_res else None, activation=activation)
    got = lut_matmul_pallas(x, packed, cb, scale, block_m=16, interpret=True,
                            **kwargs)
    want = lut_matmul_fused_ref(x, packed, cb, scale, **kwargs)
    assert rel_err(got, want) < 1e-5


def test_fused_epilogue_order_bias_act_then_residual():
    """Epilogue contract: bias BEFORE the activation, residual AFTER it."""
    x, packed, cb, scale, bias, res = _problem(8, 128, 128)
    got = lut_matmul_fused(x, packed, cb, scale, bias=bias, residual=res,
                           activation="relu", use_ref=True)
    base = lut_matmul_ref(x, packed, cb, scale, block_k=128)
    want = jax.nn.relu(base + bias) + res
    assert rel_err(got, want) < 1e-6


def test_fused_wrapper_pads_non_divisible_m_and_n():
    # M=13, N=130: neither divides the 128 blocks -> padding path
    x, packed, cb, scale, bias, res = _problem(13, 256, 130)
    got = lut_matmul_fused(x, packed, cb, scale, bias=bias, residual=res,
                           activation="gelu", block_m=128, block_n=128,
                           block_k=128, interpret=True)
    want = lut_matmul_fused_ref(x, packed, cb, scale, bias=bias, residual=res,
                                activation="gelu")
    assert got.shape == (13, 130)
    assert rel_err(got, want) < 1e-5


def test_fused_block_k_multiple_of_pack_block():
    """The kernel may take block_k = any multiple of the export pack block."""
    x, packed, cb, scale, bias, _ = _problem(16, 512, 128)
    got = lut_matmul_pallas(x, packed, cb, scale, bias=bias,
                            activation="silu", block_m=16, block_k=256,
                            pack_block=128, interpret=True)
    want = lut_matmul_fused_ref(x, packed, cb, scale, bias=bias,
                                activation="silu")
    assert rel_err(got, want) < 1e-5


def test_compat_lut_matmul_unchanged():
    x, packed, cb, scale, _, _ = _problem(32, 256, 128)
    got = lut_matmul(x, packed, cb, scale, interpret=True)
    want = lut_matmul_ref(x, packed, cb, scale, block_k=128)
    assert rel_err(got, want) < 1e-5


def test_serve_dense_fused_epilogue():
    w = jax.random.normal(jax.random.PRNGKey(5), (192, 96)) * 0.04
    comp = qat.identity_comp(w.shape)
    comp["codebook"], comp["codebook_k"] = qat.make_codebook(VALUES)
    art = export_layer(w, comp, kind="dense")
    x = jax.random.normal(jax.random.PRNGKey(6), (4, 7, 192))
    bias = jnp.linspace(-0.2, 0.2, 96)
    res = jax.random.normal(jax.random.PRNGKey(7), (4, 7, 96))
    got = serve_dense(x, art, bias=bias, residual=res, activation="relu",
                      use_ref=True)
    base = serve_dense(x, art, use_ref=True)
    assert got.shape == (4, 7, 96)
    assert rel_err(got, jax.nn.relu(base + bias) + res) < 1e-6


# ------------------------------------------------- shape diagnostics (no -O)


def test_bad_block_shapes_raise_value_error():
    x, packed, cb, scale, bias, res = _problem(16, 256, 128)
    with pytest.raises(ValueError, match="block_k"):
        lut_matmul_pallas(x, packed, cb, scale, block_m=16, block_k=100,
                          interpret=True)
    with pytest.raises(ValueError, match="bias"):
        lut_matmul_pallas(x, packed, cb, scale, block_m=16, bias=bias[:-1],
                          interpret=True)
    with pytest.raises(ValueError, match="residual"):
        lut_matmul_pallas(x, packed, cb, scale, block_m=16,
                          residual=res[:, :-1], interpret=True)
    with pytest.raises(ValueError, match="activation"):
        lut_matmul_pallas(x, packed, cb, scale, block_m=16,
                          activation="tanh", interpret=True)
    with pytest.raises(ValueError, match="pack_block"):
        lut_matmul_fused(x[:, :200], packed, cb, scale, use_ref=True)


# ------------------------------------------------------------------ autotuner


def test_candidate_blocks_are_legal():
    for bm, bn, bk in at.candidate_blocks(8, 1024, 512):
        assert bk % 128 == 0 and 1024 % bk == 0
        assert at.tile_vmem_bytes(bm, bn, bk) <= at.MachineBalance().vmem_bytes
        assert bm <= 8   # M cap: padded M, sublane-aligned


def test_roofline_prefers_wide_blocks_for_decode_shape():
    """For M=8 decode GEMMs the model must beat the hand-picked 128-cube
    (a (8, *, *) tile does strictly less padded work)."""
    m, k, n = 8, 1024, 512
    best = min(at.candidate_blocks(m, k, n),
               key=lambda b: at.roofline_time(m, k, n, b))
    assert best[0] == 8
    assert at.roofline_time(m, k, n, best) \
        < at.roofline_time(m, k, n, (128, 128, 128))


def test_autotuner_cache_roundtrip_zero_retunes(tmp_path):
    path = str(tmp_path / "cache.json")
    shapes = [(8, 512, 256), (64, 512, 512)]
    t1 = at.BlockAutotuner(path=path)
    winners = {s: t1.best(*s, backend="test") for s in shapes}
    assert t1.stats()["retune_events"] == len(shapes)
    t1.save()

    t2 = at.BlockAutotuner(path=path)   # loads at construction
    for s in shapes:
        assert t2.best(*s, backend="test") == winners[s]
    st = t2.stats()
    assert st["retune_events"] == 0 and st["hits"] == len(shapes)


def test_autotuner_measure_refines_top_k(tmp_path):
    calls = []

    def measure(blocks):
        calls.append(blocks)
        return 0.0 if blocks == calls[0] else 1.0   # first candidate "wins"

    t = at.BlockAutotuner()
    best = t.best(8, 512, 256, backend="test", measure=measure, top_k=2)
    assert len(calls) == 2 and best == calls[0]


def test_default_autotuner_honors_env_cache(tmp_path, monkeypatch):
    path = str(tmp_path / "env_cache.json")
    t = at.BlockAutotuner(path=path)
    t.best(8, 256, 128, backend=jax.default_backend())
    t.save()
    monkeypatch.setenv(at.ENV_CACHE_PATH, path)
    at.reset_default_autotuner()
    try:
        d = at.get_default_autotuner()
        d.best(8, 256, 128)
        assert d.stats() == {**d.stats(), "retune_events": 0, "hits": 1}
    finally:
        at.reset_default_autotuner()


def test_fingerprint_separates_backends_and_shapes():
    fp = at.shape_fingerprint
    base = fp(8, 512, 256, pack_block=128, backend="cpu")
    assert base != fp(8, 512, 256, pack_block=128, backend="tpu")
    assert base != fp(16, 512, 256, pack_block=128, backend="cpu")
    assert base == fp(8, 512, 256, pack_block=128, backend="cpu")


# ------------------------------------------------------- serving integration


@pytest.fixture(scope="module")
def tiny_lm():
    from repro.configs import get_config
    from repro.models.lm import build_lm
    from repro.nn.spec import init_params

    cfg = get_config("olmo-1b").scaled_down(compute_dtype="float32")
    model = build_lm(cfg)
    params = init_params(jax.random.PRNGKey(0), model.spec)
    return model, params


def test_attach_serve_artifacts_preserves_fingerprint(tiny_lm):
    from repro.core.lm_compress import attach_serve_artifacts
    from repro.serving.fleet import PlanHandle, comp_fingerprint

    model, params = tiny_lm
    plan = PlanHandle.from_compress_k(model, 8)
    comp_serve, n_units = attach_serve_artifacts(model, params, plan.comp)
    assert n_units > 0
    # artifacts are derived content: attaching them must not change identity
    assert comp_fingerprint(comp_serve) == comp_fingerprint(plan.comp)


def test_engine_lut_serve_matches_fake_quant(tiny_lm, tmp_path):
    from repro.serving import EngineConfig, ServingEngine
    from repro.serving.fleet import PlanHandle

    model, params = tiny_lm
    plan = PlanHandle.from_compress_k(model, 8)
    cache = str(tmp_path / "autotune.json")
    base = dict(max_batch=2, prompt_buckets=(8,), new_token_buckets=(8,),
                max_waves=1)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, model.cfg.vocab, size=6).astype(np.int32)
               for _ in range(2)]

    def run(config):
        eng = ServingEngine(model, params, mode="oneshot", config=config,
                            plan=plan)
        eng.warmup([(6, 4)])
        rids = [eng.submit(p, new_tokens=4) for p in prompts]
        eng.run()
        return eng, [eng.result(r).tokens for r in rids]

    eng_fq, toks_fq = run(EngineConfig(**base))
    eng_lut, toks_lut = run(EngineConfig(**base, lut_serve=True,
                                         autotune_cache=cache))
    assert eng_lut.serve_units > 0
    assert toks_lut == toks_fq          # token-for-token parity
    assert os.path.exists(cache)        # winners persisted after warmup


def test_engine_config_validates_lut_knobs():
    from repro.serving import EngineConfig

    with pytest.raises(ValueError, match="lut_serve"):
        EngineConfig(lut_serve="yes")
    with pytest.raises(ValueError, match="lut_use_ref"):
        EngineConfig(lut_use_ref=1)
    with pytest.raises(ValueError, match="autotune_cache"):
        EngineConfig(autotune_cache=7)
