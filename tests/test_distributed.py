"""Sharding rules, divisibility guard, input specs, loop-corrected HLO cost,
and an 8-device mini dry-run (subprocess) proving the multi-device path."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.distributed.sharding import DEFAULT_RULES
from repro.launch import train as TR
from repro.launch.hlo_cost import loop_corrected_cost
from repro.models.lm import build_lm


def test_rules_lookup_and_replace():
    r = DEFAULT_RULES
    assert r.lookup("vocab") == "model"
    assert r.lookup("batch") == ("pod", "data")
    assert r.lookup("nonexistent") is None
    r2 = r.replace(vocab=None, extra="model")
    assert r2.lookup("vocab") is None
    assert r2.lookup("extra") == "model"
    assert r.lookup("vocab") == "model"  # original untouched


def _mini_mesh():
    from jax.sharding import Mesh

    # single-device "mesh" with the production axis names: sizes 1 so every
    # guard decision is exercised without fake devices
    dev = np.asarray(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def test_logical_to_spec_guard_on_trivial_mesh():
    from repro.distributed.sharding import logical_to_spec

    mesh = _mini_mesh()
    spec = logical_to_spec(("vocab", "embed"), (512, 128), mesh)
    # axes of size 1 -> everything replicated, no error
    assert all(p is None for p in spec)


def test_batch_specs_all_archs_all_shapes():
    for arch in ("olmo-1b", "internvl2-26b", "whisper-large-v3",
                 "mamba2-1.3b"):
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if shape.kind == "decode":
                continue
            specs = TR.batch_specs(cfg, shape)
            assert "tokens" in specs
            if cfg.prefix_len:
                assert specs["prefix_embeds"].shape[1] == cfg.prefix_len
                assert (specs["tokens"].shape[1]
                        == shape.seq - cfg.prefix_len)
            if cfg.encoder_decoder:
                assert specs["enc_embeds"].shape[1] == shape.seq
                assert specs["tokens"].shape[1] <= TR.WHISPER_DECODER_LEN


def test_cache_axes_cover_every_leaf():
    for arch in ("gemma3-4b", "mamba2-1.3b", "recurrentgemma-2b",
                 "whisper-large-v3"):
        model = build_lm(get_config(arch))
        spec = TR.decode_cache_specs(model, SHAPES["decode_32k"])
        axes = TR.cache_axes(spec)
        leaves_s = jax.tree.leaves(spec)
        leaves_a = jax.tree.leaves(
            axes, is_leaf=lambda x: isinstance(x, tuple))
        assert len(leaves_s) == len(leaves_a)
        for s, a in zip(leaves_s, leaves_a):
            assert len(a) == len(s.shape), (arch, s.shape, a)


def test_cache_axes_kv_seq_mode():
    model = build_lm(get_config("qwen2.5-14b"))
    spec = TR.decode_cache_specs(model, SHAPES["decode_32k"])
    axes = TR.cache_axes(spec, kv_seq_shard=True)
    k_axes = axes["groups"]["g0"]["k"]
    assert "kv_seq" in k_axes
    assert "kv_heads" not in k_axes


def test_windowed_cache_is_bounded():
    model = build_lm(get_config("recurrentgemma-2b"))
    spec = TR.decode_cache_specs(model, SHAPES["long_500k"])
    # local-attn layers cache at most `window` positions even at 500k context
    k = spec["groups"]["g2"]["k"]
    assert k.shape[2] == get_config("recurrentgemma-2b").window
    # recurrent layers carry fixed-size states
    assert spec["groups"]["g0"]["h"].shape[-1] == 2560


def test_loop_corrected_cost_scan_exact():
    def body(h, w):
        return jnp.dot(h, w), None

    def f(ws, x):
        h, _ = jax.lax.scan(body, x, ws)
        return h

    ws = jnp.zeros((5, 64, 64))
    x = jnp.zeros((64, 64))
    comp = jax.jit(f).lower(ws, x).compile()
    got = loop_corrected_cost(comp.as_text())
    assert got["flops"] == pytest.approx(5 * 2 * 64**3, rel=1e-6)


def test_mini_dryrun_8_devices():
    """Lower+compile a reduced arch on an 8-device (2x4) mesh in a subprocess
    — the real multi-device path end to end (sharded state, batch, comp)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.configs import get_config
        from repro.launch import train as TR
        from repro.models.lm import build_lm

        cfg = get_config("gemma3-4b").scaled_down(
            n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
            d_ff=128, vocab=512, window=16)
        model = build_lm(cfg)
        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
        step_cfg = TR.StepConfig(q_block=8, kv_block=8)
        state = TR.abstract_train_state(model)
        state_sh = TR.train_state_shardings(model, mesh)
        from repro.configs.base import Shape
        shape = Shape("t", "train", 32, 8)
        specs = TR.batch_specs(cfg, shape)
        specs_sh = TR.batch_shardings(specs, mesh)
        comp = TR.comp_abstract(model)
        comp_sh = TR.comp_shardings(model, mesh)
        step = TR.make_train_step(model, step_cfg, mesh)
        jitted = jax.jit(step, in_shardings=(state_sh, specs_sh, comp_sh),
                         out_shardings=(state_sh, None), donate_argnums=(0,))
        with mesh:
            compiled = jitted.lower(state, specs, comp).compile()
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca  # jax<0.5 wraps
        assert ca["flops"] > 0
        # and actually RUN one sharded step with concrete data
        cstate = TR.init_train_state(model, step_cfg)
        from repro.core.lm_compress import init_lm_comp
        ccomp = init_lm_comp(model)
        batch = {"tokens": jnp.zeros((32, 8), jnp.int32),
                 "labels": jnp.zeros((32, 8), jnp.int32)}
        with mesh:
            new_state, metrics = jitted(cstate, batch, ccomp)
        assert bool(jnp.isfinite(metrics["loss"]))
        print("MINI_DRYRUN_OK", float(metrics["loss"]))
    """)
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, cwd=os.getcwd(), timeout=900)
    assert "MINI_DRYRUN_OK" in out.stdout, out.stderr[-3000:]
