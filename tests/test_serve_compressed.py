"""Compressed serving parity suite: exported 4-bit LUT path vs QAT forward.

Headline guarantee of the serving subsystem (`repro.core.export` +
`comp_mode="serve"`): for any post-schedule comp tree, the packed-artifact
forward through `lut_matmul` matches the QAT fake-quant forward to float
round-off — per layer and for full-model logits, across codebook sizes,
pruned and unpruned layers, and shapes that exercise the M/N/K padding path.

Everything runs on CPU: the Pallas kernel in interpret mode for the smaller
checks, the jnp oracle (`use_ref_kernel`) for the big full-model sweeps.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import qat
from repro.core.export import (
    export_layer,
    export_model,
    export_summary,
    serve_conv,
    serve_dense,
    servable,
)
from repro.core.runner import CnnRunner
from repro.core.schedule import ScheduleConfig, energy_prioritized_compression
from repro.core.weight_selection import SelectionConfig
from repro.data.synthetic import SyntheticImages
from repro.kernels.lut_matmul.ops import (
    compress_layer_weights,
    encode_weights,
    lut_matmul,
    pack_indices,
)
from repro.kernels.lut_matmul.ref import unpack_indices
from repro.nn import cnn
from repro.nn.layers import QuantConfig
from repro.nn.spec import init_params

CODEBOOKS = {
    4: [-96, -32, 0, 64],
    8: [-112, -64, -32, -8, 0, 16, 48, 96],
    16: [-120, -96, -72, -56, -40, -28, -16, -8, 0, 8, 20, 32, 52, 76, 100,
         124],
}


def restricted_comp(model, params, values, prune=()):
    """Identity comp with every layer restricted to ``values``; layers named
    in ``prune`` additionally get a 50% magnitude mask."""
    comp = {}
    for cl in model.comp_layers:
        w = model.get_weight(params, cl.name)
        c = qat.identity_comp(w.shape, w.dtype)
        c["codebook"], c["codebook_k"] = qat.make_codebook(values)
        if cl.name in prune:
            c["mask"] = qat.magnitude_prune_mask(w, 0.5)
        comp[cl.name] = c
    return comp


def logits_pair(model, params, state, comp, arts, x, *, use_ref=False):
    l_fake, _, _ = model.apply(params, state, x, train=False,
                               qcfg=QuantConfig.on(), comp=comp)
    l_serve, _, _ = model.apply(params, state, x, train=False,
                                qcfg=QuantConfig.serve(use_ref_kernel=use_ref),
                                comp=comp, serve=arts)
    return l_fake, l_serve


def rel_err(got, want):
    return float(jnp.linalg.norm(got - want)
                 / jnp.maximum(jnp.linalg.norm(want), 1e-9))


# ------------------------------------------------------------ per-layer parity


@pytest.mark.parametrize("k", [4, 8, 16])
@pytest.mark.parametrize("kdim,n", [(128, 64), (200, 130), (75, 10)])
def test_dense_layer_parity(k, kdim, n):
    """Exported dense layer == fake-quant dense, incl. non-multiple-of-block
    M/N/K (the padding path) and a pruning mask."""
    key = jax.random.PRNGKey(k * 1000 + kdim + n)
    w = jax.random.normal(key, (kdim, n)) * 0.05
    comp = qat.identity_comp(w.shape)
    comp["codebook"], comp["codebook_k"] = qat.make_codebook(CODEBOOKS[k])
    comp["mask"] = qat.magnitude_prune_mask(w, 0.4)

    art = export_layer(w, comp, kind="dense")
    assert art is not None and art.k_dim == kdim and art.n_dim == n

    x = jax.random.normal(jax.random.fold_in(key, 1), (37, kdim))
    got = serve_dense(x, art, interpret=True)
    want = x @ qat.fake_quant_weight(w, comp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("k", [4, 8, 16])
@pytest.mark.parametrize("stride,padding", [(1, "SAME"), (2, "SAME"),
                                            (1, "VALID")])
def test_conv_layer_parity(k, stride, padding):
    """Exported conv layer through im2col + LUT GEMM == fake-quant lax.conv."""
    key = jax.random.PRNGKey(k + stride * 10)
    w = jax.random.normal(key, (3, 3, 5, 12)) * 0.1   # K = 45: padding path
    comp = qat.identity_comp(w.shape)
    comp["codebook"], comp["codebook_k"] = qat.make_codebook(CODEBOOKS[k])

    art = export_layer(w, comp, kind="conv")
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 9, 9, 5))
    got = serve_conv(x, art, stride=stride, padding=padding, interpret=True)
    w_fake = qat.fake_quant_weight(w, comp)
    want = jax.lax.conv_general_dilated(
        x, w_fake, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_unrestricted_layer_is_not_servable():
    comp = qat.identity_comp((16, 8))
    assert not servable(comp)
    assert export_layer(jnp.ones((16, 8)), comp) is None


def test_non_square_conv_kernel_rejected_at_export():
    """serve_conv assumes square kernels; export must refuse, not mis-serve."""
    w = jnp.ones((1, 3, 4, 8))
    comp = qat.identity_comp(w.shape)
    comp["codebook"], comp["codebook_k"] = qat.make_codebook(CODEBOOKS[4])
    with pytest.raises(ValueError, match="square"):
        export_layer(w, comp, kind="conv")


# ----------------------------------------------------------- full-model parity


@pytest.mark.parametrize("k", [4, 8, 16])
def test_lenet_full_model_parity(k):
    """Full LeNet logits: every layer served on the (interpreted) Pallas LUT
    kernel vs the fake-quant forward; two layers pruned."""
    model = cnn.lenet5()
    key = jax.random.PRNGKey(k)
    params = init_params(key, model.spec)
    comp = restricted_comp(model, params, CODEBOOKS[k],
                           prune=("conv2", "fc1"))
    arts = export_model(model, params, comp)
    assert set(arts) == {cl.name for cl in model.comp_layers}

    x = jax.random.normal(key, (4, 32, 32, 3))
    l_fake, l_serve = logits_pair(model, params, {}, comp, arts, x)
    assert rel_err(l_serve, l_fake) < 1e-3
    # served model still classifies identically on this batch
    np.testing.assert_array_equal(np.asarray(jnp.argmax(l_serve, -1)),
                                  np.asarray(jnp.argmax(l_fake, -1)))


@pytest.mark.parametrize("k", [4, 16])
def test_resnet8_full_model_parity(k):
    """Reduced ResNet-20 (3 stages x 1 block): full-model logits through the
    serve path (jnp oracle for CPU speed; the Pallas path is covered by the
    per-layer and LeNet tests) vs fake-quant, pruned + unpruned layers."""
    model = cnn.resnet8()
    key = jax.random.PRNGKey(100 + k)
    params = init_params(key, model.spec)
    state = init_params(key, model.state_spec)
    comp = restricted_comp(model, params, CODEBOOKS[k],
                           prune=("s2b1/conv1", "fc"))
    arts = export_model(model, params, comp)
    assert set(arts) == {cl.name for cl in model.comp_layers}
    summary = export_summary(arts)
    assert summary["compression_vs_int8"] > 1.0

    x = jax.random.normal(key, (2, 32, 32, 3))
    l_fake, l_serve = logits_pair(model, params, state, comp, arts, x,
                                  use_ref=True)
    assert rel_err(l_serve, l_fake) < 1e-5


def test_resnet8_one_layer_on_pallas_path():
    """Spot-check one ResNet conv (stride-2 downsample) on the interpreted
    Pallas kernel inside the full model: mixed serve/fallback dispatch."""
    model = cnn.resnet8()
    key = jax.random.PRNGKey(5)
    params = init_params(key, model.spec)
    state = init_params(key, model.state_spec)
    comp = restricted_comp(model, params, CODEBOOKS[8])
    # only restrict s2b1 layers -> others have k=0 and must fall back
    for cl in model.comp_layers:
        if not cl.name.startswith("s2b1"):
            comp[cl.name]["codebook_k"] = jnp.zeros((), jnp.int32)
    arts = export_model(model, params, comp)
    assert set(arts) == {"s2b1/conv1", "s2b1/conv2", "s2b1/down"}

    x = jax.random.normal(key, (2, 32, 32, 3))
    l_fake, l_serve = logits_pair(model, params, state, comp, arts, x)
    # deep nets amplify fp32 accumulation-order noise: a ~1e-7 difference in
    # a mid-network conv can push a downstream activation across a
    # fake_quant_act rounding boundary (one full int8 step). 1e-2 on logits
    # still means the two paths agree on every quantization bin but a few.
    assert rel_err(l_serve, l_fake) < 1e-2


def test_serve_without_artifacts_falls_back_to_fake_quant():
    """comp_mode='serve' with an empty artifact dict must be exactly the
    fake-quant forward (per-layer fallback)."""
    model = cnn.lenet5()
    key = jax.random.PRNGKey(9)
    params = init_params(key, model.spec)
    comp = restricted_comp(model, params, CODEBOOKS[8])
    x = jax.random.normal(key, (2, 32, 32, 3))
    l_fake, l_serve = logits_pair(model, params, {}, comp, {}, x)
    np.testing.assert_array_equal(np.asarray(l_fake), np.asarray(l_serve))


# ------------------------------------------------------- pruning honored as 0


def test_pruned_weights_serve_as_exact_zero_without_zero_in_codebook():
    """Even when C_l lacks 0, exported pruned positions dequantize to exactly
    0 (0 is force-included): zero-gated MACs stay zero-gated on the array."""
    key = jax.random.PRNGKey(11)
    w = jax.random.normal(key, (64, 32)) * 0.05
    comp = qat.identity_comp(w.shape)
    comp["codebook"], comp["codebook_k"] = qat.make_codebook([-80, -20, 30, 90])
    comp["mask"] = qat.magnitude_prune_mask(w, 0.5)

    art = export_layer(w, comp, kind="dense")
    assert 0 in [int(v) for v in np.asarray(art.codebook)]
    idx = unpack_indices(art.packed, art.block_k)[: art.k_dim]
    w_served = np.asarray(art.codebook, np.int32)[np.asarray(idx)]
    mask = np.asarray(comp["mask"])
    assert (w_served[mask == 0] == 0).all()


# --------------------------------------------------- schedule regression test


@pytest.fixture(scope="module")
def scheduled_lenet():
    """Tiny LeNet through QAT + a one-layer compression schedule."""
    runner = CnnRunner(cnn.lenet5(), SyntheticImages(seed=3), batch_size=64,
                       lr=2e-3, seed=0)
    params, state, opt_state, comp = runner.init()
    params, state, opt_state, _ = runner.train(params, state, opt_state,
                                               comp, 200)
    stats = runner.profile(params, state, comp, n_batches=1, max_tiles=6)
    cfg = ScheduleConfig(prune_ratios=(0.5,), k_targets=(16,), delta_acc=0.08,
                         finetune_steps=20, trial_finetune_steps=10,
                         eval_batches=2, max_layers=1, min_energy_share=0.0)
    sel = SelectionConfig(k_init=20, k_target=16, delta_acc=0.08,
                          score_batches=1, accept_batches=1,
                          max_score_candidates=4)
    params, state, opt_state, comp, result = energy_prioritized_compression(
        runner, params, state, opt_state, comp, stats, cfg, sel)
    return runner, params, state, comp, result, cfg


def test_schedule_export_serve_accuracy_matches_reported(scheduled_lenet):
    """schedule -> export -> compressed inference: the serve-path accuracy on
    the schedule's own eval batches equals the reported acc_final (parity
    means at most a borderline sample or two can flip)."""
    runner, params, state, comp, result, cfg = scheduled_lenet
    accepted = [d for d in result.decisions if d.accepted]
    assert accepted, "schedule must accept its one layer at delta=0.08"
    arts = export_model(runner.model, params, comp)
    assert accepted[0].layer in arts

    qserve = QuantConfig.serve(use_ref_kernel=True)
    correct = 0
    for i in range(cfg.eval_batches):
        x, y = runner.dataset.batch(i, runner.batch_size, "val")
        logits, _, _ = runner.model.apply(params, state, x, train=False,
                                          qcfg=qserve, comp=comp, serve=arts)
        correct += int(jnp.sum((jnp.argmax(logits, -1) == y)))
    acc_serve = correct / (cfg.eval_batches * runner.batch_size)
    noise = 2.0 / (cfg.eval_batches * runner.batch_size)
    assert abs(acc_serve - result.acc_final) <= noise, (
        acc_serve, result.acc_final)


# ------------------------------------------------------- kernel edge cases


def test_pack_indices_rejects_bad_k():
    idx = jnp.zeros((100, 8), jnp.int32)
    with pytest.raises(ValueError, match="multiple of block_k"):
        pack_indices(idx, 128)
    with pytest.raises(ValueError, match="even"):
        pack_indices(jnp.zeros((128, 8), jnp.int32), 127)


def test_lut_matmul_rejects_unpadded_k():
    x = jnp.zeros((8, 100))
    packed = jnp.zeros((50, 8), jnp.int8)
    with pytest.raises(ValueError, match="multiple of pack_block"):
        lut_matmul(x, packed, jnp.zeros((16,), jnp.int8), jnp.ones((8,)),
                   interpret=True)


def test_encode_weights_stable_with_duplicate_codebook_entries():
    """Padded/duplicate codebooks must encode to indices that decode to the
    same value the projection picked (ties -> lowest index)."""
    cb = jnp.asarray([-40, -40, 0, 10, 10, 10] + [10] * 10, jnp.int32)
    w = jnp.asarray([[-40, -39, 0, 10, 10, 7]], jnp.int32)
    idx = encode_weights(w, cb)
    decoded = np.asarray(cb)[np.asarray(idx)]
    np.testing.assert_array_equal(decoded, [[-40, -40, 0, 10, 10, 10]])
    # duplicates resolve to the first occurrence
    assert int(idx[0, 0]) == 0 and int(idx[0, 3]) == 3


def test_all_negative_codebook_roundtrip():
    """An all-negative restricted set survives encode -> pack -> unpack ->
    dequant and matches the fake-quant projection."""
    key = jax.random.PRNGKey(13)
    w = jax.random.normal(key, (128, 24)) * 0.05
    values = [-120, -80, -45, -20, -5]
    packed, cb, scale = compress_layer_weights(w, values, block_k=128)
    assert set(int(v) for v in np.asarray(cb)).issubset(set(values))

    comp = qat.identity_comp(w.shape)
    comp["codebook"], comp["codebook_k"] = qat.make_codebook(values)
    w_fake = qat.fake_quant_weight(w, comp)
    idx = unpack_indices(packed, 128)
    w_served = (np.asarray(cb, np.int32)[np.asarray(idx)]
                * np.asarray(scale)[None, :])
    np.testing.assert_allclose(w_served, np.asarray(w_fake), rtol=1e-6,
                               atol=1e-7)


def test_compress_layer_weights_force_includes_zero_for_masks():
    key = jax.random.PRNGKey(17)
    w = jax.random.normal(key, (128, 16)) * 0.05
    mask = qat.magnitude_prune_mask(w, 0.5)
    values = [-90, -30, 40, 110]           # no 0
    packed, cb, scale = compress_layer_weights(w, values, mask=mask,
                                               block_k=128)
    cb_vals = [int(v) for v in np.asarray(cb)]
    assert 0 in cb_vals
    idx = unpack_indices(packed, 128)
    w_served = np.asarray(cb, np.int32)[np.asarray(idx)]
    assert (w_served[np.asarray(mask) == 0] == 0).all()
    # a full 16-value set without 0 + mask cannot fit the forced 0
    full_no_zero = [v for v in CODEBOOKS[16] if v != 0] + [127]
    with pytest.raises(ValueError, match="forced 0"):
        compress_layer_weights(w, full_no_zero, mask=mask)
