"""NN substrate + CNN model tests (incl. QAT forward paths and im2col)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import qat
from repro.core.runner import CnnRunner
from repro.core.stats import conv_weight_matrix, im2col
from repro.data.synthetic import SyntheticImages, SyntheticTokens
from repro.nn import cnn
from repro.nn.layers import QuantConfig
from repro.nn.spec import abstract_params, init_params, param_axes, spec_count


@pytest.mark.parametrize("stride,padding", [(1, "SAME"), (1, "VALID"), (2, "SAME")])
def test_im2col_matches_conv(stride, padding):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 9, 9, 4))
    w = jax.random.normal(key, (3, 3, 4, 5))
    y_conv = jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    cols = im2col(x, (3, 3), stride, padding)       # (K, N*Ho*Wo)
    w_mat = conv_weight_matrix(w)                   # (Cout, K)
    y_mat = (w_mat @ cols).T.reshape(y_conv.shape)
    np.testing.assert_allclose(np.asarray(y_conv), np.asarray(y_mat), rtol=1e-4, atol=1e-4)


def test_qat_fake_quant_roundtrip():
    key = jax.random.PRNGKey(1)
    w = jax.random.normal(key, (32, 16))
    comp = qat.identity_comp(w.shape)
    wq = qat.fake_quant_weight(w, comp)
    # quantization error bounded by scale/2 per channel
    scale = qat.weight_scale(w)
    assert float(jnp.max(jnp.abs(wq - w))) <= float(jnp.max(scale)) * 0.51


def test_qat_codebook_projection():
    cb, k = qat.make_codebook([-100, -50, 0, 50, 100])
    q = jnp.asarray([-128, -70, -10, 20, 60, 127])
    proj = qat.project_to_codebook(q, cb, k)
    np.testing.assert_array_equal(np.asarray(proj), [-100, -50, 0, 0, 50, 100])
    # k=0 => identity
    proj0 = qat.project_to_codebook(q, cb, jnp.zeros((), jnp.int32))
    np.testing.assert_array_equal(np.asarray(proj0), np.asarray(q))


def test_qat_weights_land_in_codebook():
    key = jax.random.PRNGKey(2)
    w = jax.random.normal(key, (64, 32))
    comp = qat.identity_comp(w.shape)
    cb, k = qat.make_codebook([-96, -32, 0, 32, 96])
    comp["codebook"], comp["codebook_k"] = cb, k
    w_int = qat.quantize_weight_int(w, comp)
    allowed = {-96, -32, 0, 32, 96}
    assert set(np.unique(np.asarray(w_int))).issubset(allowed)


def test_qat_ste_gradient_flows():
    w = jnp.ones((8, 8)) * 0.37
    comp = qat.identity_comp(w.shape)

    def f(w):
        return jnp.sum(qat.fake_quant_weight(w, comp) ** 2)

    g = jax.grad(f)(w)
    assert float(jnp.sum(jnp.abs(g))) > 0


def test_magnitude_prune_mask():
    key = jax.random.PRNGKey(3)
    w = jax.random.normal(key, (100,))
    mask = qat.magnitude_prune_mask(w, 0.7)
    kept = float(jnp.sum(mask))
    assert abs(kept - 30) <= 1
    # largest magnitude weights kept
    assert float(mask[jnp.argmax(jnp.abs(w))]) == 1.0


@pytest.mark.parametrize("build,n_params_min", [
    (cnn.lenet5, 60_000), (cnn.resnet20, 250_000), (cnn.resnet8, 70_000),
])
def test_cnn_forward_shapes_and_finite(build, n_params_min):
    model = build()
    assert spec_count(model.spec) > n_params_min
    key = jax.random.PRNGKey(0)
    params = init_params(key, model.spec)
    state = init_params(key, model.state_spec)
    x = jax.random.normal(key, (4, 32, 32, 3))
    logits, new_state, _ = model.apply(params, state, x, train=True)
    assert logits.shape == (4, model.num_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # eval mode with fresh state also finite
    logits2, _, _ = model.apply(params, new_state, x, train=False)
    assert bool(jnp.all(jnp.isfinite(logits2)))


def test_cnn_qat_forward_close_to_float():
    model = cnn.lenet5()
    key = jax.random.PRNGKey(0)
    params = init_params(key, model.spec)
    x = jax.random.normal(key, (4, 32, 32, 3))
    runner_comp = {cl.name: qat.identity_comp(model.get_weight(params, cl.name).shape)
                   for cl in model.comp_layers}
    lf, _, _ = model.apply(params, {}, x, train=False)
    lq, _, _ = model.apply(params, {}, x, train=False, qcfg=QuantConfig.on(),
                           comp=runner_comp)
    # int8 QAT should track the float model closely at init
    rel = float(jnp.linalg.norm(lq - lf) / jnp.maximum(jnp.linalg.norm(lf), 1e-6))
    assert rel < 0.15


def test_resnet50_builds_abstractly():
    model = cnn.resnet50()
    ab = abstract_params(model.spec)
    n = sum(np.prod(l.shape) for l in jax.tree.leaves(ab))
    assert n > 20_000_000  # ~23.5M params
    axes = param_axes(model.spec)
    axes_leaves = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    ab_leaves = jax.tree.leaves(ab)
    assert len(axes_leaves) == len(ab_leaves)
    for a, l in zip(axes_leaves, ab_leaves):
        assert len(a) == len(l.shape)


def test_cnn_taps_capture():
    model = cnn.lenet5()
    key = jax.random.PRNGKey(0)
    params = init_params(key, model.spec)
    comp = {cl.name: qat.identity_comp(model.get_weight(params, cl.name).shape)
            for cl in model.comp_layers}
    x = jax.random.normal(key, (2, 32, 32, 3))
    _, _, taps = model.apply(params, {}, x, train=False, qcfg=QuantConfig.on(),
                             comp=comp, capture_taps=True)
    assert set(taps.keys()) == {cl.name for cl in model.comp_layers}
    for t in taps.values():
        assert t["w_int"].dtype == jnp.int32
        assert int(jnp.max(jnp.abs(t["w_int"]))) <= 127


def test_lenet_learns_synthetic():
    """A few hundred QAT steps must beat chance decisively."""
    runner = CnnRunner(cnn.lenet5(), SyntheticImages(seed=1), batch_size=64,
                       lr=2e-3, seed=0)
    params, state, opt_state, comp = runner.init()
    acc0 = runner.accuracy(params, state, comp, n_batches=4)
    params, state, opt_state, _ = runner.train(params, state, opt_state, comp, 150)
    acc1 = runner.accuracy(params, state, comp, n_batches=4)
    assert acc1 > max(2 * acc0, 0.5), (acc0, acc1)


def test_synthetic_tokens_deterministic_and_learnable_structure():
    ds = SyntheticTokens(vocab=128, seed=0)
    x1, y1 = ds.batch(3, 4, 16)
    x2, y2 = ds.batch(3, 4, 16)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    # labels follow the affine map for most positions
    pred = (x1 * ds._a + ds._b) % ds.vocab
    agree = float(jnp.mean((pred == y1).astype(jnp.float32)))
    assert agree > 0.6
