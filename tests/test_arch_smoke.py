"""Per-architecture smoke tests: reduced same-family config, one forward +
one train-grad step + one decode step on CPU; shape and finiteness checks.

The FULL configs are exercised only via the dry-run (abstract lowering)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_ARCHS, SHAPES, cell_is_runnable, get_config, skip_reason
from repro.models.config import active_param_count, model_param_count
from repro.models.lm import build_lm
from repro.nn.spec import abstract_params, init_params


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_reduced_forward_train_decode(arch):
    cfg_full = get_config(arch)
    cfg = cfg_full.scaled_down()
    model = build_lm(cfg)
    params = init_params(jax.random.PRNGKey(0), model.spec)

    b, s = 2, 24
    key = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab),
    }
    if cfg.prefix_len:
        batch["prefix_embeds"] = jax.random.normal(
            key, (b, cfg.prefix_len, cfg.d_model))
    if cfg.encoder_decoder:
        batch["enc_embeds"] = jax.random.normal(key, (b, 16, cfg.d_model))

    # forward
    logits, _ = model.forward(
        params, batch["tokens"],
        prefix_embeds=batch.get("prefix_embeds"),
        enc_embeds=batch.get("enc_embeds"),
        q_block=8, kv_block=8)
    exp_s = s + (cfg.prefix_len or 0)
    assert logits.shape == (b, exp_s, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits[..., :cfg.vocab])))

    # one train grad step
    def loss_fn(p):
        return model.loss(p, batch, q_block=8, kv_block=8)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert gnorm > 0.0

    # one decode step from a prefilled cache
    lg, cache = model.prefill(
        params, batch["tokens"][:, :8], max_len=32,
        prefix_embeds=batch.get("prefix_embeds"),
        enc_embeds=batch.get("enc_embeds"),
        cache_dtype=jnp.float32, q_block=8, kv_block=8)
    lg_d, cache = model.decode_step(params, cache, batch["tokens"][:, 8:9])
    assert lg_d.shape == (b, 1, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(lg_d[..., :cfg.vocab])))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_param_count_sane(arch):
    """Abstract spec of the FULL config: no allocation, count must be within
    30% of the analytic estimate (catches mis-wired configs)."""
    import math

    cfg = get_config(arch)
    model = build_lm(cfg)
    ab = abstract_params(model.spec)
    n = sum(math.prod(l.shape) for l in jax.tree.leaves(ab))
    est = model_param_count(cfg)
    assert 0.7 < n / est < 1.3, (n, est)
    # family-plausible magnitudes
    floor = {"internvl2-26b": 15e9, "qwen2.5-14b": 12e9,
             "phi3.5-moe-42b-a6.6b": 35e9, "moonshot-v1-16b-a3b": 15e9}
    if arch in floor:
        assert n > floor[arch]
    assert active_param_count(cfg) <= est + 1


def test_cell_applicability_matrix():
    cells = [(a, s) for a in ALL_ARCHS for s in SHAPES]
    assert len(cells) == 40
    runnable = [c for c in cells if cell_is_runnable(*c)]
    skipped = [c for c in cells if not cell_is_runnable(*c)]
    assert len(skipped) == 8  # long_500k for the 8 full-attention archs
    assert all(s == "long_500k" for _, s in skipped)
    for a, s in skipped:
        assert skip_reason(a, s)
    long_ok = {a for a, s in runnable if s == "long_500k"}
    assert long_ok == {"recurrentgemma-2b", "mamba2-1.3b"}
