"""MSR truncation as a third schedule axis: exact truncation semantics,
serial-vs-batched decision parity with MSR candidates enabled, rollback,
plan round-trip of MSR decisions, and LUT-GEMM serve parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import qat
from repro.core.export import export_layer, serve_dense
from repro.core.runner import CnnRunner
from repro.core.schedule import (
    LayerDecision,
    ScheduleConfig,
    _config_order,
    energy_prioritized_compression,
)
from repro.core.weight_selection import SelectionConfig, msr_comp
from repro.data.synthetic import SyntheticImages
from repro.nn import cnn
from repro.pipeline.plan import CompressionPlan, decision_dict
from repro.pipeline.schema import validate_plan_doc


# ------------------------------------------------------ truncation semantics


def test_msr_truncate_exact_values():
    cases = [
        (127, 3, 112),   # 1111111 -> 1110000
        (127, 1, 64),
        (5, 1, 4),       # 101 -> 100
        (5, 2, 4),       # 101 -> 100 (third significant bit dropped)
        (5, 3, 5),       # 101 -> 101 (all three significant bits kept)
        (-6, 2, -6),     # 110 keeps both bits
        (-7, 2, -6),     # 111 -> 110, sign preserved
        (7, 2, 6),
        (1, 1, 1),
        (0, 3, 0),
    ]
    for v, bits, want in cases:
        got = int(qat.msr_truncate_int(jnp.asarray(v, jnp.int32), bits))
        assert got == want, (v, bits, got, want)


def test_msr_truncate_zero_bits_is_identity():
    q = jnp.arange(-128, 128, dtype=jnp.int32)
    np.testing.assert_array_equal(np.asarray(qat.msr_truncate_int(q, 0)),
                                  np.asarray(q))


def test_msr_truncate_under_vmap():
    """The batched sweep vmaps over a stacked (n,) msr_bits axis."""
    q = jnp.asarray([[127, -33, 5, 0]], jnp.int32)
    qs = jnp.broadcast_to(q, (3, 1, 4))
    bits = jnp.asarray([0, 1, 3], jnp.int32)
    out = jax.vmap(qat.msr_truncate_int)(qs, bits)
    np.testing.assert_array_equal(
        np.asarray(out),
        [[[127, -33, 5, 0]], [[64, -32, 4, 0]], [[112, -32, 5, 0]]])


# ----------------------------------------------------------- comp plumbing


def test_identity_comp_has_msr_off_and_legacy_comps_work():
    comp = qat.identity_comp((6, 3))
    assert int(comp["msr_bits"]) == 0
    w = jax.random.normal(jax.random.PRNGKey(0), (6, 3)) * 0.1
    q0 = qat.quantize_weight_int(w, comp)
    legacy = {k: v for k, v in comp.items() if k != "msr_bits"}
    np.testing.assert_array_equal(np.asarray(qat.quantize_weight_int(w, legacy)),
                                  np.asarray(q0))
    np.testing.assert_array_equal(np.asarray(qat.fake_quant_weight(w, legacy)),
                                  np.asarray(qat.fake_quant_weight(w, comp)))


def test_msr_comp_updates_only_target_layer():
    comp = {"a": qat.identity_comp((4, 2)), "b": qat.identity_comp((4, 2))}
    out = msr_comp(comp, "a", 3)
    assert int(out["a"]["msr_bits"]) == 3
    assert int(out["b"]["msr_bits"]) == 0
    assert int(comp["a"]["msr_bits"]) == 0          # functional update
    w = jax.random.normal(jax.random.PRNGKey(1), (4, 2)) * 0.2
    q_plain = qat.quantize_weight_int(w, comp["a"])
    q_msr = qat.quantize_weight_int(w, out["a"])
    np.testing.assert_array_equal(
        np.asarray(q_msr), np.asarray(qat.msr_truncate_int(q_plain, 3)))


def test_config_order_default_unchanged_and_msr_ranking():
    # default msr_bits=(0,) must reproduce the historical (prune, k) order
    assert _config_order(ScheduleConfig()) == [
        (0.7, 16, 0), (0.7, 24, 0), (0.7, 32, 0),
        (0.5, 16, 0), (0.5, 24, 0), (0.5, 32, 0),
        (0.3, 16, 0), (0.3, 24, 0), (0.3, 32, 0)]
    # MSR-on candidates rank more aggressive than MSR-off; fewer bits first
    cfg = ScheduleConfig(prune_ratios=(0.5,), k_targets=(8, 16),
                         msr_bits=(0, 2, 3))
    assert _config_order(cfg) == [
        (0.5, 8, 2), (0.5, 16, 2), (0.5, 8, 3), (0.5, 16, 3),
        (0.5, 8, 0), (0.5, 16, 0)]


def test_pipeline_config_validates_msr_range():
    from repro.pipeline.config import PipelineConfig

    cfg = PipelineConfig()
    cfg.schedule.msr_bits = (0, 3)
    cfg.validate()
    cfg.schedule.msr_bits = (9,)
    with pytest.raises(ValueError, match="msr_bits"):
        cfg.validate()


# ------------------------------------------------------------- seeded parity


def _runner():
    return CnnRunner(cnn.lenet5(), SyntheticImages(seed=3, noise=1.4),
                     batch_size=64, lr=2e-3, seed=0)


@pytest.fixture(scope="module")
def trained_lenet():
    runner = _runner()
    params, state, opt_state, comp = runner.init()
    params, state, opt_state, _ = runner.train(params, state, opt_state,
                                               comp, 120)
    stats = runner.profile(params, state, comp, n_batches=1, max_tiles=4)
    return runner, params, state, opt_state, comp, stats


def _msr_cfg(mode, delta=0.06):
    return ScheduleConfig(
        search_mode=mode,
        prune_ratios=(0.5,), k_targets=(8,), msr_bits=(2, 0),
        delta_acc=delta, finetune_steps=6, trial_finetune_steps=4,
        eval_batches=1, max_layers=1, min_energy_share=0.0)


_SEL = SelectionConfig(k_init=10, k_target=8, delta_acc=0.06,
                       score_batches=1, accept_batches=1,
                       max_score_candidates=3)


def test_batched_matches_serial_with_msr_candidates(trained_lenet):
    """Decision parity including the msr component of each decision: the
    batched sweep must pick exactly the candidate the serial walk accepts."""
    runner, params, state, opt_state, comp, stats = trained_lenet
    results = {}
    for mode in ("serial", "batched"):
        _, _, _, c2, res = energy_prioritized_compression(
            runner, params, state, opt_state, comp, stats,
            _msr_cfg(mode), _SEL)
        results[mode] = (c2, res)

    (_, ser), (_, bat) = results["serial"], results["batched"]
    key = lambda d: (d.layer, d.prune_ratio, d.k, d.msr, d.accepted,
                     tuple(tuple(t) for t in d.tried))
    assert [key(d) for d in ser.decisions] == [key(d) for d in bat.decisions]
    assert ser.acc0 == bat.acc0
    # an accepted candidate carries its msr depth into the comp tree
    for mode, (c2, res) in results.items():
        for d in res.decisions:
            if d.accepted:
                assert int(c2[d.layer]["msr_bits"]) == (d.msr or 0), mode


def test_rejected_msr_candidates_leave_state_untouched(trained_lenet):
    runner, params, state, opt_state, comp, stats = trained_lenet
    cfg = _msr_cfg("batched", delta=-1.0)   # floor acc0 + 1: all reject
    p2, s2, o2, c2, res = energy_prioritized_compression(
        runner, params, state, opt_state, comp, stats, cfg, _SEL)
    assert all(not d.accepted for d in res.decisions)
    assert all(d.msr is None for d in res.decisions)
    assert res.energy_saving == 0.0
    for got, want in ((p2, params), (o2, opt_state)):
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for name in comp:
        for leaf in ("mask", "codebook", "codebook_k", "msr_bits"):
            np.testing.assert_array_equal(np.asarray(c2[name][leaf]),
                                          np.asarray(comp[name][leaf]))


# ------------------------------------------------------- plan round-trip


def test_plan_roundtrip_with_msr_decisions(tmp_path):
    dec = LayerDecision(
        layer="conv2", share=0.6, prune_ratio=0.5, k=8,
        energy_before=10.0, energy_after=7.0, accuracy=0.9, accepted=True,
        tried=[(0.5, 8, 2)], msr=2)
    dec_off = LayerDecision(
        layer="fc1", share=0.4, prune_ratio=None, k=None,
        energy_before=5.0, energy_after=5.0, accuracy=0.9, accepted=False,
        tried=[(0.5, 8, 2), (0.5, 8, 0)])
    comp = {"conv2": qat.identity_comp((4, 3))}
    comp["conv2"]["codebook"], comp["conv2"]["codebook_k"] = \
        qat.make_codebook([-8, 0, 8])
    comp["conv2"]["msr_bits"] = jnp.asarray(2, jnp.int32)
    plan = CompressionPlan(
        target={"kind": "cnn", "arch": "lenet5"},
        decisions=[decision_dict(dec), decision_dict(dec_off)],
        metrics={"energy_before": 15.0, "energy_after": 12.0},
        shares={"conv2": 0.6, "fc1": 0.4},
        comp=comp)
    for s in ("profile", "energy_model", "schedule"):
        plan.mark_done(s)
    base = tmp_path / "plan_msr"
    plan.save(base)
    back = CompressionPlan.load(base)
    assert back.decisions[0]["msr"] == 2
    assert back.decisions[0]["tried"] == [[0.5, 8, 2]]
    assert back.decisions[1]["msr"] is None
    assert back.decisions[1]["tried"] == [[0.5, 8, 2], [0.5, 8, 0]]
    assert int(back.comp["conv2"]["msr_bits"]) == 2
    # schema gate accepts MSR decisions (and old 2-element tried lists)
    import json
    doc = json.loads((tmp_path / "plan_msr.json").read_text())
    assert all(g["pass"] for g in validate_plan_doc(doc)
               if g["name"] == "plan_decisions_sane")
    # summary surfaces the msr column
    assert plan.summary()["layers"][0]["msr"] == 2


def test_schema_rejects_out_of_range_msr(tmp_path):
    dec = decision_dict(LayerDecision(
        layer="l", share=1.0, prune_ratio=0.5, k=8, energy_before=2.0,
        energy_after=1.0, accuracy=0.9, accepted=True,
        tried=[(0.5, 8, 9)], msr=9))
    doc = {"schema_version": 1, "completed": ["profile", "energy_model",
                                             "schedule"],
           "decisions": [dec], "shares": {"l": 1.0},
           "metrics": {"energy_before": 2.0, "energy_after": 1.0},
           "arrays": {"x": {}}}
    gates = {g["name"]: g["pass"] for g in validate_plan_doc(doc)}
    assert gates["plan_decisions_sane"] is False


# ------------------------------------------------------------ serve parity


def test_lut_serve_parity_for_msr_truncated_weights():
    """export_layer + serve_dense must match x @ fake_quant_weight when the
    comp carries an MSR depth — the serving encode truncates before the
    codebook projection exactly like the QAT forward."""
    key = jax.random.PRNGKey(11)
    w = jax.random.normal(key, (128, 64)) * 0.05
    comp = qat.identity_comp(w.shape)
    comp["codebook"], comp["codebook_k"] = qat.make_codebook(
        [-96, -64, -48, -32, -16, -8, 0, 8, 16, 32, 48, 64, 96, 127])
    comp["msr_bits"] = jnp.asarray(2, jnp.int32)

    art = export_layer(w, comp, kind="dense", layout="out_last", block_k=128)
    assert art is not None
    x = jax.random.normal(jax.random.fold_in(key, 1), (16, 128))
    y_serve = serve_dense(x, art, interpret=True)
    y_fake = x @ qat.fake_quant_weight(w, comp)
    np.testing.assert_allclose(np.asarray(y_serve), np.asarray(y_fake),
                               rtol=1e-4, atol=1e-4)
    # and the truncation actually changed the served weights
    comp_off = dict(comp)
    comp_off["msr_bits"] = jnp.asarray(0, jnp.int32)
    y_off = x @ qat.fake_quant_weight(w, comp_off)
    assert not np.allclose(np.asarray(y_fake), np.asarray(y_off))
