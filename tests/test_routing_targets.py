"""Routing-aware compression targets (ISSUE 10).

Three layers of coverage:

- pure-numpy unit tests for the `repro.core.routing_stats` share / ladder
  helpers (deterministic, no jax);
- a calibration-trace determinism test on the reduced MoE model — two
  collections under the same seed must be bit-identical;
- a reduced `MoETarget` pipeline driven through `export`, asserting the
  hot-gentler / cold-aggressive k assignment, the LUT-serve parity metric,
  and the structured export skip report.
"""

import numpy as np
import pytest

from repro.core import routing_stats as rs

# ------------------------------------------------------------- share math


def test_traffic_shares_rows_sum_to_one():
    rng = np.random.default_rng(0)
    counts = rng.integers(0, 50, size=(3, 4)).astype(np.float64)
    shares = rs.traffic_shares(counts)
    assert shares.shape == counts.shape
    np.testing.assert_allclose(shares.sum(axis=-1), np.ones(3), atol=1e-12)
    assert (shares >= 0).all()


def test_traffic_shares_zero_row_falls_back_to_uniform():
    counts = np.array([[0.0, 0.0, 0.0, 0.0], [1.0, 3.0, 0.0, 0.0]])
    shares = rs.traffic_shares(counts)
    np.testing.assert_allclose(shares[0], np.full(4, 0.25))
    np.testing.assert_allclose(shares[1], [0.25, 0.75, 0.0, 0.0])


def test_traffic_shares_accepts_1d_counts():
    shares = rs.traffic_shares(np.array([2.0, 6.0]))
    assert shares.shape == (1, 2)
    np.testing.assert_allclose(shares[0], [0.25, 0.75])


def test_activity_shares_normalize_and_zero_fallback():
    shares = rs.activity_shares(np.array([1.0, 3.0]))
    np.testing.assert_allclose(shares, [0.25, 0.75])
    np.testing.assert_allclose(rs.activity_shares(np.zeros(4)),
                               np.full(4, 0.25))


# --------------------------------------------------------------- k ladder


def test_assign_rank_k_hot_gets_gentlest():
    ks = rs.assign_rank_k(np.array([0.1, 0.5, 0.3, 0.1]), (4, 8, 16))
    assert ks[1] == 16                       # hottest expert, gentlest k
    assert set(int(k) for k in ks) <= {4, 8, 16}


def test_assign_rank_k_monotone_in_share():
    rng = np.random.default_rng(7)
    for _ in range(20):
        shares = rng.random(rng.integers(2, 9))
        shares /= shares.sum()
        ks = rs.assign_rank_k(shares, (2, 4, 8, 16))
        for i in range(len(shares)):
            for j in range(len(shares)):
                if shares[i] > shares[j]:
                    assert ks[i] >= ks[j], (shares, ks)


def test_assign_rank_k_deterministic_ties_and_empty_ladder():
    ks_a = rs.assign_rank_k(np.full(4, 0.25), (4, 16))
    ks_b = rs.assign_rank_k(np.full(4, 0.25), (16, 4))   # order-insensitive
    np.testing.assert_array_equal(ks_a, ks_b)
    with pytest.raises(ValueError, match="empty"):
        rs.assign_rank_k(np.array([1.0]), ())


def test_traffic_weighted_energy_uniform_is_identity():
    e = np.array([3.0, 5.0, 7.0, 9.0])
    np.testing.assert_allclose(
        rs.traffic_weighted_energy(e, np.full(4, 0.25)), e)
    hot = rs.traffic_weighted_energy(e, np.array([0.7, 0.1, 0.1, 0.1]))
    assert hot[0] > e[0] and hot[1] < e[1]
    # the layer total stays comparable to the dense accounting
    np.testing.assert_allclose(hot.sum(),
                               (e * [0.7, 0.1, 0.1, 0.1]).sum() * 4)


# -------------------------------------------- calibration-trace collection


@pytest.fixture(scope="module")
def moe_model():
    """Reduced phi-MoE model + fresh params (no QAT) for routing tests."""
    import jax

    from repro.configs import get_config
    from repro.models.lm import build_lm
    from repro.nn.spec import init_params

    acfg = get_config("phi3.5-moe-42b-a6.6b").scaled_down(
        compute_dtype="float32")
    model = build_lm(acfg)
    params = init_params(jax.random.PRNGKey(0), model.spec)
    return model, params


def test_routing_collection_deterministic_under_seed(moe_model):
    model, params = moe_model
    kw = dict(batches=2, batch_size=2, seq_len=16, seed=0)
    a = rs.collect_lm_routing_stats(model, params, **kw)
    b = rs.collect_lm_routing_stats(model, params, **kw)
    assert a.tokens == b.tokens == 2 * 2 * 16
    assert a.moe_counts.keys() == b.moe_counts.keys()
    assert len(a.moe_counts) >= 1
    for unit, counts in a.moe_counts.items():
        assert counts.ndim == 2                 # (layers, experts)
        np.testing.assert_array_equal(counts, b.moe_counts[unit])
        shares = rs.traffic_shares(counts)
        np.testing.assert_allclose(shares.sum(axis=-1),
                                   np.ones(counts.shape[0]), atol=1e-12)
    # round-trip through the plan.stats array encoding
    c = rs.RoutingStats.from_arrays(a.as_arrays())
    assert c.tokens == a.tokens
    for unit, counts in a.moe_counts.items():
        np.testing.assert_array_equal(c.moe_counts[unit], counts)


def test_export_skip_report_on_unrestricted_comp(moe_model):
    """Fresh `init_lm_comp` codebooks exceed the serve-kernel budget, so
    every unit must land in the skip report with a reason — never silently
    vanish from the artifact dict."""
    from repro.core.lm_compress import (export_lm_matmuls, init_lm_comp,
                                        lm_comp_layers)
    from repro.pipeline.targets import _slice_key

    model, params = moe_model
    arts, skips = export_lm_matmuls(model, params, init_lm_comp(model))
    assert arts == {}
    # one skip entry per unit *slice*; together they cover every comp unit
    skipped_bases = {_slice_key(s["unit"])[0] for s in skips}
    assert skipped_bases == set(lm_comp_layers(model))
    assert {s["reason"] for s in skips} <= {"inactive_codebook", "no_layout",
                                            "codebook_too_large"}
    assert all(s["unit"] for s in skips)


# ------------------------------------------------------ routed pipelines


@pytest.fixture(scope="module")
def moe_plan():
    from repro.pipeline import Pipeline, reduced_moe_config

    pipe = Pipeline(reduced_moe_config())
    pipe.run_until("export")
    return pipe.plan


def test_moe_pipeline_routes_experts_hot_to_gentle(moe_plan):
    from repro.pipeline.targets import _slice_key

    routed = [d for d in moe_plan.decisions if "traffic_share" in d]
    assert len(routed) >= 8                    # >= layers x experts slices
    # hot experts keep gentler (larger-k) codebooks within each (unit, layer)
    groups = {}
    for d in routed:
        path, li, ei = _slice_key(d["layer"])
        assert ei is not None
        assert 0.0 <= d["traffic_share"] <= 1.0
        groups.setdefault((path, li), []).append(
            (d["traffic_share"], d["k"]))
    assert groups
    for pairs in groups.values():
        for share_i, k_i in pairs:
            for share_j, k_j in pairs:
                if share_i > share_j:
                    assert k_i >= k_j, pairs


def test_moe_pipeline_export_parity_and_energy(moe_plan):
    m = moe_plan.metrics
    assert m["export_parity_max_rel_err"] < 2e-2
    assert m["export_skipped"] == 0
    assert (moe_plan.stats or {}).get("export", {}).get("skip_report") == []
    assert m["energy_after"] < m["energy_before"]
    assert m["routed_units"] >= 8
    assert m["routing_tokens"] > 0
    # plan round-trips the routing arrays for resume
    assert any(key.startswith("moe:") for key in moe_plan.stats["routing"])
