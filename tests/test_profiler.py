"""Batched whole-layer profiler vs the per-tile pure-jnp oracle.

The contract under test: for a multi-tile layer (including partial tiles that
`pad_to_tiles` zero-pads), ONE batched invocation — Pallas kernel in
interpret mode, vectorized oracle, or the sharded path — reproduces the sum
of per-tile `tile_transition_stats` calls bin-for-bin on all four outputs
(energy_sum, count, group_hist, act_hist)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mac_model import DEFAULT_COEFFS
from repro.core.profiler import (
    batched_layer_stats,
    batched_stats_oracle,
    gather_layer_tiles,
    profile_layer,
    sharded_layer_stats,
)
from repro.core.stats import (
    TILE,
    collect_layer_stats,
    pad_to_tiles,
    tile_transition_stats,
)

NAMES = ("energy_sum", "count", "group_hist", "act_hist")


def _layer_case(key, m=96, k=70, n=150, max_tiles=4):
    """Partial-tile layer (96x70 @ 70x150 -> 2x2x3 padded tiles) + sampled
    batch, alongside the per-tile oracle reference sums."""
    w = jax.random.randint(key, (m, k), -100, 100, dtype=jnp.int32)
    x = jax.random.randint(jax.random.fold_in(key, 1), (k, n), -100, 100,
                           dtype=jnp.int32)
    w_pad, x_pad = pad_to_tiles(w, x)
    mt, kt = w_pad.shape[0] // TILE, w_pad.shape[1] // TILE
    nt = x_pad.shape[1] // TILE
    total = mt * kt * nt
    n_s = min(max_tiles, total)
    choice = jax.random.choice(key, total, (n_s,), replace=False)
    w_tiles, a_blocks = gather_layer_tiles(w_pad, x_pad, choice)

    ref = None
    for i in range(n_s):
        o = tile_transition_stats(w_tiles[i], a_blocks[i], DEFAULT_COEFFS)
        ref = o if ref is None else [a + b for a, b in zip(ref, o)]
    return w, x, w_tiles, a_blocks, choice, ref


def _assert_stats_match(got, ref, context, atol=0.5):
    for g, r, name in zip(got, ref, NAMES):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r), rtol=1e-3,
                                   atol=atol, err_msg=f"{context}:{name}")


def test_gather_matches_manual_slicing():
    key = jax.random.PRNGKey(11)
    w, x, w_tiles, a_blocks, choice, _ = _layer_case(key, max_tiles=6)
    w_pad, x_pad = pad_to_tiles(w, x)
    kt = w_pad.shape[1] // TILE
    nt = x_pad.shape[1] // TILE
    for b, idx in enumerate(jax.device_get(choice)):
        idx = int(idx)
        mi, rest = divmod(idx, kt * nt)
        ki, ni = divmod(rest, nt)
        want_w = w_pad[mi * TILE:(mi + 1) * TILE, ki * TILE:(ki + 1) * TILE].T
        want_a = x_pad[ki * TILE:(ki + 1) * TILE, ni * TILE:(ni + 1) * TILE]
        np.testing.assert_array_equal(np.asarray(w_tiles[b]),
                                      np.asarray(want_w))
        np.testing.assert_array_equal(np.asarray(a_blocks[b]),
                                      np.asarray(want_a))


def test_batched_oracle_matches_per_tile_oracle():
    key = jax.random.PRNGKey(0)
    _, _, w_tiles, a_blocks, _, ref = _layer_case(key, max_tiles=6)
    mask = jnp.ones((w_tiles.shape[0],), jnp.float32)
    got = batched_stats_oracle(w_tiles, a_blocks, mask, DEFAULT_COEFFS)
    _assert_stats_match(got, ref, "batched_oracle")


def test_batched_kernel_interpret_matches_oracle():
    """Batched Pallas kernel (interpret) bin-for-bin vs the oracle, on a
    multi-tile batch with a short streaming axis (interpret-mode cost)."""
    key = jax.random.PRNGKey(2)
    _, _, w_tiles, a_blocks, _, _ = _layer_case(key, max_tiles=3)
    a_short = a_blocks[:, :, :12]
    ref = None
    for i in range(w_tiles.shape[0]):
        o = tile_transition_stats(w_tiles[i], a_short[i], DEFAULT_COEFFS)
        ref = o if ref is None else [a + b for a, b in zip(ref, o)]
    got = batched_layer_stats(w_tiles, a_short, DEFAULT_COEFFS,
                              use_kernel=True, interpret=True)
    _assert_stats_match(got, ref, "batched_kernel")


def test_zero_padding_tiles_contribute_nothing():
    """Batch padding (all-zero tiles, mask 0) must not change any bin —
    oracle and kernel paths."""
    key = jax.random.PRNGKey(3)
    _, _, w_tiles, a_blocks, _, _ = _layer_case(key, max_tiles=3)
    a_short = a_blocks[:, :, :12]
    n = w_tiles.shape[0]
    mask = jnp.ones((n,), jnp.float32)
    w_padded = jnp.pad(w_tiles, ((0, 2), (0, 0), (0, 0)))
    a_padded = jnp.pad(a_short, ((0, 2), (0, 0), (0, 0)))
    mask_padded = jnp.pad(mask, (0, 2))

    ref = batched_stats_oracle(w_tiles, a_short, mask, DEFAULT_COEFFS)
    got = batched_stats_oracle(w_padded, a_padded, mask_padded,
                               DEFAULT_COEFFS)
    _assert_stats_match(got, ref, "oracle_pad", atol=1e-2)

    got_k = batched_layer_stats(w_padded, a_padded, DEFAULT_COEFFS,
                                mask=mask_padded, use_kernel=True,
                                interpret=True)
    _assert_stats_match(got_k, ref, "kernel_pad", atol=1e-2)


def test_sharded_path_matches_unsharded():
    key = jax.random.PRNGKey(5)
    _, _, w_tiles, a_blocks, _, ref = _layer_case(key, max_tiles=5)
    got = sharded_layer_stats(w_tiles, a_blocks, DEFAULT_COEFFS)
    _assert_stats_match(got, ref, "sharded")


def test_profile_layer_equals_seed_loop_semantics():
    """`collect_layer_stats` (now batched) must reproduce the seed's looped
    accumulation: same sampling key -> same tiles -> same statistics."""
    key = jax.random.PRNGKey(4)
    w = jax.random.randint(key, (96, 70), -100, 100, dtype=jnp.int32)
    x = jax.random.randint(jax.random.fold_in(key, 1), (70, 150), -100, 100,
                           dtype=jnp.int32)
    w_pad, x_pad = pad_to_tiles(w, x)
    kt = w_pad.shape[1] // TILE
    nt = x_pad.shape[1] // TILE
    mt = w_pad.shape[0] // TILE
    total = mt * kt * nt
    n_s = 4
    choice = jax.device_get(
        jax.random.choice(key, total, (n_s,), replace=False))
    ref = None
    for idx in choice:
        idx = int(idx)
        mi, rest = divmod(idx, kt * nt)
        ki, ni = divmod(rest, nt)
        w_t = w_pad[mi * TILE:(mi + 1) * TILE, ki * TILE:(ki + 1) * TILE].T
        a_b = x_pad[ki * TILE:(ki + 1) * TILE, ni * TILE:(ni + 1) * TILE]
        o = tile_transition_stats(w_t, a_b, DEFAULT_COEFFS)
        ref = o if ref is None else [a + b for a, b in zip(ref, o)]

    s = collect_layer_stats(w, x, max_tiles=n_s, key=key)
    _assert_stats_match(
        (s.energy_sum, s.count, s.group_hist, s.act_hist), ref,
        "collect_layer_stats")
    assert s.n_transitions == n_s * TILE * TILE * (TILE - 1)


def test_profile_layer_samples_all_tiles_when_few():
    key = jax.random.PRNGKey(7)
    w = jax.random.randint(key, (64, 64), -50, 50, dtype=jnp.int32)
    x = jax.random.randint(jax.random.fold_in(key, 1), (64, 64), -50, 50,
                           dtype=jnp.int32)
    s = profile_layer(w, x, max_tiles=100, key=key)
    # 1 tile total, 64*64 MACs x 63 transitions each
    assert s.n_transitions == TILE * TILE * (TILE - 1)
    assert float(jnp.sum(s.count)) == s.n_transitions


def test_runner_caches_stats_for_energy_models():
    from repro.core.runner import CnnRunner
    from repro.data.synthetic import SyntheticImages
    from repro.nn import cnn

    runner = CnnRunner(cnn.lenet5(10), SyntheticImages(num_classes=10, seed=3),
                       batch_size=16)
    params, state, _, comp = runner.init()
    with pytest.raises(ValueError):
        runner.energy_models(params, comp)  # no profile yet, no stats given
    stats = runner.profile(params, state, comp, max_tiles=2)
    models = runner.energy_models(params, comp)  # cached stats
    assert set(models) == set(stats)
    models2 = runner.energy_models(params, comp, stats)
    for name in models:
        assert models[name].energy == models2[name].energy


def test_multi_device_sharded_profiling_subprocess():
    """Force 4 host devices in a subprocess and check the sharded profiler
    (auto-selected by `profile_layer`) matches the single-device result."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        assert jax.device_count() == 4, jax.device_count()
        from repro.core.profiler import batched_stats_oracle, \\
            gather_layer_tiles, profile_layer
        from repro.core.mac_model import DEFAULT_COEFFS
        from repro.core.stats import TILE, pad_to_tiles
        key = jax.random.PRNGKey(4)
        w = jax.random.randint(key, (96, 70), -100, 100, dtype=jnp.int32)
        x = jax.random.randint(jax.random.fold_in(key, 1), (70, 150), -100,
                               100, dtype=jnp.int32)
        s = profile_layer(w, x, max_tiles=6, key=key)  # auto-sharded, 6->8 pad
        w_pad, x_pad = pad_to_tiles(w, x)
        total = (w_pad.shape[0] // TILE) * (w_pad.shape[1] // TILE) * \\
            (x_pad.shape[1] // TILE)
        ch = jax.random.choice(key, total, (6,), replace=False)
        wt, ab = gather_layer_tiles(w_pad, x_pad, ch)
        ref = batched_stats_oracle(wt, ab, jnp.ones((6,), jnp.float32),
                                   DEFAULT_COEFFS)
        np.testing.assert_allclose(np.asarray(s.energy_sum),
                                   np.asarray(ref[0]), rtol=1e-3, atol=1e-2)
        np.testing.assert_allclose(np.asarray(s.group_hist),
                                   np.asarray(ref[2]), atol=0.5)
        np.testing.assert_allclose(np.asarray(s.act_hist),
                                   np.asarray(ref[3]), atol=0.5)
        print("OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=4").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
